"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret mode on CPU), plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,Hkv,D", [
    (1, 128, 4, 4, 64), (2, 256, 8, 2, 64), (1, 128, 4, 1, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, Hkv, D, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = _rand(ks[0], (B, S, H, D), dtype)
    k = _rand(ks[1], (B, S, Hkv, D), dtype)
    v = _rand(ks[2], (B, S, Hkv, D), dtype)
    out = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    want = ref.ref_attention(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window,softcap", [(64, None), (None, 30.0),
                                            (32, 50.0)])
def test_flash_attention_window_softcap(window, softcap):
    B, S, H, D = 1, 128, 2, 64
    ks = jax.random.split(jax.random.key(1), 3)
    q, k, v = (_rand(ks[i], (B, S, H, D), jnp.float32) for i in range(3))
    out = ops.flash_attention(q, k, v, window=window, softcap=softcap,
                              block_q=32, block_k=32)
    want = ref.ref_attention(q, k, v, window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_noncausal():
    B, S, H, D = 1, 64, 2, 64
    ks = jax.random.split(jax.random.key(2), 3)
    q, k, v = (_rand(ks[i], (B, S, H, D), jnp.float32) for i in range(3))
    out = ops.flash_attention(q, k, v, causal=False, block_q=32, block_k=32)
    want = ref.ref_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Smax,H,Hkv,D,pos", [
    (2, 256, 8, 2, 64, 0), (2, 256, 8, 2, 64, 100), (1, 512, 4, 4, 128, 511),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, Smax, H, Hkv, D, pos, dtype):
    ks = jax.random.split(jax.random.key(3), 3)
    q = _rand(ks[0], (B, H, D), dtype)
    kc = _rand(ks[1], (B, Smax, Hkv, D), dtype)
    vc = _rand(ks[2], (B, Smax, Hkv, D), dtype)
    out = ops.decode_attention(q, kc, vc, jnp.asarray(pos, jnp.int32),
                               block_k=64)
    want = ref.ref_decode_attention(q, kc, vc, pos)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_ragged_pos(dtype):
    """Vector per-row positions (continuous batching): each row attends to
    its own valid window only."""
    B, Smax, H, Hkv, D = 4, 256, 8, 2, 64
    ks = jax.random.split(jax.random.key(6), 3)
    q = _rand(ks[0], (B, H, D), dtype)
    kc = _rand(ks[1], (B, Smax, Hkv, D), dtype)
    vc = _rand(ks[2], (B, Smax, Hkv, D), dtype)
    pos = jnp.asarray([0, 17, 128, 255], jnp.int32)
    out = ops.decode_attention(q, kc, vc, pos, block_k=64)
    want = ref.ref_decode_attention(q, kc, vc, pos)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("Smax,block_k", [(192, 128), (100, 64)])
def test_decode_attention_nondividing_window(Smax, block_k):
    """Cache windows that block_k doesn't divide (e.g. an engine max_seq of
    prompt+max_new+slack) lower via the largest dividing block."""
    B, H, Hkv, D = 2, 4, 2, 64
    ks = jax.random.split(jax.random.key(9), 3)
    q = _rand(ks[0], (B, H, D), jnp.float32)
    kc = _rand(ks[1], (B, Smax, Hkv, D), jnp.float32)
    vc = _rand(ks[2], (B, Smax, Hkv, D), jnp.float32)
    pos = jnp.asarray([7, Smax - 1], jnp.int32)
    out = ops.decode_attention(q, kc, vc, pos, block_k=block_k)
    want = ref.ref_decode_attention(q, kc, vc, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_kv_major_layout():
    """The KV-major serving layout ([B,Hkv,S,D]) gives the same result as
    the default [B,S,Hkv,D] without the wrapper transpose."""
    B, Smax, H, Hkv, D = 2, 128, 4, 2, 64
    ks = jax.random.split(jax.random.key(7), 3)
    q = _rand(ks[0], (B, H, D), jnp.float32)
    kc = _rand(ks[1], (B, Smax, Hkv, D), jnp.float32)
    vc = _rand(ks[2], (B, Smax, Hkv, D), jnp.float32)
    pos = jnp.asarray([3, 100], jnp.int32)
    a = ops.decode_attention(q, kc, vc, pos, block_k=32)
    b = ops.decode_attention(q, kc.transpose(0, 2, 1, 3),
                             vc.transpose(0, 2, 1, 3), pos, block_k=32,
                             kv_layout="bhsd")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_decode_attention_paged_matches_dense():
    """Paged flash-decode through a shuffled page table equals dense decode
    over the same logical KV, including rows with partially-mapped tables."""
    B, Smax, H, Hkv, D, ps = 3, 128, 8, 2, 64, 16
    P = Smax // ps
    n_pages = 32
    ks = jax.random.split(jax.random.key(8), 3)
    q = _rand(ks[0], (B, H, D), jnp.float32)
    kc = _rand(ks[1], (B, Smax, Hkv, D), jnp.float32)
    vc = _rand(ks[2], (B, Smax, Hkv, D), jnp.float32)
    pos = jnp.asarray([5, 63, 127], jnp.int32)
    rng = np.random.default_rng(0)
    pages = rng.permutation(n_pages)[:B * P].reshape(B, P)
    kp = np.zeros((n_pages, Hkv, ps, D), np.float32)
    vp = np.zeros((n_pages, Hkv, ps, D), np.float32)
    for b in range(B):
        for j in range(P):
            kp[pages[b, j]] = np.asarray(kc)[b, j * ps:(j + 1) * ps] \
                .transpose(1, 0, 2)
            vp[pages[b, j]] = np.asarray(vc)[b, j * ps:(j + 1) * ps] \
                .transpose(1, 0, 2)
    pt = pages.astype(np.int32)
    pt[0, 1:] = n_pages            # row 0 (pos 5 < ps): rest unmapped
    out = ops.decode_attention_paged(jnp.asarray(q), jnp.asarray(kp),
                                     jnp.asarray(vp), jnp.asarray(pt), pos)
    want = ref.ref_decode_attention(q, kc, vc, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    wantp = ref.ref_decode_attention_paged(jnp.asarray(q), jnp.asarray(kp),
                                           jnp.asarray(vp), jnp.asarray(pt),
                                           pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(wantp),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# SPT gather / scatter
# ---------------------------------------------------------------------------

@given(n_pages=st.integers(1, 32), seed=st.integers(0, 100))
@settings(max_examples=12, deadline=None)
def test_spt_gather_property(n_pages, seed):
    rng = np.random.default_rng(seed)
    n_arena = n_pages + int(rng.integers(0, 16))
    arena = jnp.asarray(rng.normal(size=(n_arena, 256)).astype(np.float32))
    spt = jnp.asarray(rng.choice(n_arena, n_pages, replace=False)
                      .astype(np.int32))
    out = ops.spt_gather(arena, spt)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.ref_spt_gather(arena, spt)))


def test_spt_roundtrip():
    """scatter(gather(x)) restores the arena pages the SPT references."""
    rng = np.random.default_rng(0)
    n_arena, n_pages = 24, 16
    arena = jnp.asarray(rng.normal(size=(n_arena, 128)).astype(np.float32))
    spt = jnp.asarray(rng.choice(n_arena, n_pages, replace=False)
                      .astype(np.int32))
    logical = ops.spt_gather(arena, spt)
    back = ops.spt_scatter(logical, spt, n_arena)
    np.testing.assert_array_equal(np.asarray(back)[np.asarray(spt)],
                                  np.asarray(arena)[np.asarray(spt)])


# ---------------------------------------------------------------------------
# dual-tenant matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m_ls,m_be,K,N,sm_be", [
    (128, 256, 128, 128, 0.3), (256, 128, 256, 256, 0.5),
])
def test_dual_tenant_matmul(m_ls, m_be, K, N, sm_be):
    ks = jax.random.split(jax.random.key(4), 4)
    a_ls = _rand(ks[0], (m_ls, K), jnp.float32)
    b_ls = _rand(ks[1], (K, N), jnp.float32)
    a_be = _rand(ks[2], (m_be, K), jnp.float32)
    b_be = _rand(ks[3], (K, N), jnp.float32)
    o_ls, o_be = ops.dual_tenant_matmul(a_ls, b_ls, a_be, b_be, sm_be=sm_be,
                                        block_m=64, block_n=64, block_k=64)
    w_ls, w_be = ref.ref_dual_tenant_matmul(a_ls, b_ls, a_be, b_be)
    np.testing.assert_allclose(np.asarray(o_ls), np.asarray(w_ls), rtol=1e-5,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(o_be), np.asarray(w_be), rtol=1e-5,
                               atol=1e-4)


def test_dual_tenant_schedule_quota():
    """In every scheduling round while both tenants have tiles, BE holds at
    most floor(sm_be * round) tiles (the SM_BE quota)."""
    from repro.kernels.dual_tenant_matmul import _schedule
    order = _schedule(n_ls=16, n_be=64, sm_be=0.25, round_tiles=8)
    assert [o for o, _ in order].count(0) == 16
    assert [o for o, _ in order].count(1) == 64
    # while LS tiles remain, each window of 8 has <= 2 BE tiles
    upto = max(i for i, (o, _) in enumerate(order) if o == 0)
    for s in range(0, upto - 8, 8):
        window = [o for o, _ in order[s:s + 8]]
        assert window.count(1) <= 2, (s, window)


def test_dual_tenant_schedule_no_starvation():
    """A fractional quota below one tile per round (sm_be * round_tiles < 1)
    accumulates as credit: BE tiles interleave before LS drains instead of
    starving until the tail, and every tile is scheduled exactly once."""
    from repro.kernels.dual_tenant_matmul import _schedule
    order = _schedule(n_ls=40, n_be=6, sm_be=0.05, round_tiles=8)
    owners = [o for o, _ in order]
    assert owners.count(0) == 40 and owners.count(1) == 6
    # sm_be=0.05 earns 0.4 credit per 8-tile round -> first BE tile by
    # round 3 (credit 1.2), well before the 40 LS tiles drain
    first_be = owners.index(1)
    assert first_be < 40, f"BE starved until LS drained (index {first_be})"
    # per-tenant tile ids stay in order and complete
    assert [r for o, r in order if o == 0] == list(range(40))
    assert [r for o, r in order if o == 1] == list(range(6))
    # quota still respected while both run
    upto = max(i for i, o in enumerate(owners) if o == 0)
    for s in range(0, upto - 8, 8):
        assert owners[s:s + 8].count(1) <= 1, (s, owners[s:s + 8])


# ---------------------------------------------------------------------------
# dual-tenant fused attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B_ls,B_be,S,H,Hkv,D,sm_be", [
    (2, 3, 256, 4, 4, 64, 0.3), (1, 2, 128, 4, 2, 64, 0.5),
])
def test_dual_tenant_attention(B_ls, B_be, S, H, Hkv, D, sm_be):
    """Both tenants of the fused grid match the single-tenant causal flash
    kernel bit-for-bit — the quota interleave only permutes placement."""
    ks = jax.random.split(jax.random.key(21), 6)
    q1 = _rand(ks[0], (B_ls, S, H, D), jnp.float32)
    k1 = _rand(ks[1], (B_ls, S, Hkv, D), jnp.float32)
    v1 = _rand(ks[2], (B_ls, S, Hkv, D), jnp.float32)
    q2 = _rand(ks[3], (B_be, S, H, D), jnp.float32)
    k2 = _rand(ks[4], (B_be, S, Hkv, D), jnp.float32)
    v2 = _rand(ks[5], (B_be, S, Hkv, D), jnp.float32)
    o1, o2 = ops.dual_tenant_attention(q1, k1, v1, q2, k2, v2, sm_be=sm_be,
                                       block_q=64, block_k=64)
    w1 = ops.flash_attention(q1, k1, v1, causal=True, block_q=64, block_k=64)
    w2 = ops.flash_attention(q2, k2, v2, causal=True, block_q=64, block_k=64)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(w1))
    np.testing.assert_array_equal(np.asarray(o2), np.asarray(w2))


def test_dual_tenant_attention_quota_invariant():
    """sm_be permutes only the schedule: outputs are bit-identical across
    quota settings."""
    ks = jax.random.split(jax.random.key(22), 3)
    q = _rand(ks[0], (2, 128, 4, 64), jnp.float32)
    k = _rand(ks[1], (2, 128, 4, 64), jnp.float32)
    v = _rand(ks[2], (2, 128, 4, 64), jnp.float32)
    outs = [ops.dual_tenant_attention(q, k, v, q, k, v, sm_be=s,
                                      block_q=64, block_k=64)
            for s in (0.1, 0.5, 0.9)]
    for o_ls, o_be in outs[1:]:
        np.testing.assert_array_equal(np.asarray(o_ls),
                                      np.asarray(outs[0][0]))
        np.testing.assert_array_equal(np.asarray(o_be),
                                      np.asarray(outs[0][1]))


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,T,H,K,P,chunk", [
    (1, 128, 2, 16, 32, 32), (2, 64, 4, 8, 8, 16), (1, 256, 1, 64, 64, 64),
])
def test_ssd_scan_sweep(B, T, H, K, P, chunk):
    ks = jax.random.split(jax.random.key(5), 4)
    q = _rand(ks[0], (B, T, H, K), jnp.float32)
    k = _rand(ks[1], (B, T, H, K), jnp.float32)
    v = _rand(ks[2], (B, T, H, P), jnp.float32)
    log_w = -jnp.abs(_rand(ks[3], (B, T, H, K), jnp.float32)) * 0.2
    out = ops.ssd_scan(q, k, v, log_w, chunk=chunk)
    want = ref.ref_ssd_scan(q, k, v, log_w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@given(seed=st.integers(0, 1000))
@settings(max_examples=8, deadline=None)
def test_ssd_scan_property_decay_extremes(seed):
    """With decay ~ 0 (log_w very negative) the scan reduces to per-token
    kv outer products; with decay = 1 (log_w = 0) it is a running sum."""
    rng = np.random.default_rng(seed)
    B, T, H, K, P = 1, 32, 1, 8, 8
    q = jnp.asarray(rng.normal(size=(B, T, H, K)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, T, H, K)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, T, H, P)).astype(np.float32))
    zero = jnp.zeros((B, T, H, K), jnp.float32)
    out = ops.ssd_scan(q, k, v, zero, chunk=8)
    want = ref.ref_ssd_scan(q, k, v, zero)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# chunked-prefill attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Smax,Sq,H,Hkv,D", [
    (2, 256, 8, 8, 2, 64), (1, 128, 16, 4, 4, 64), (2, 128, 1, 4, 2, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_prefill_attention_sweep(B, Smax, Sq, H, Hkv, D, dtype):
    """Sq-token query chunks at per-row start positions attend to their
    cached-context window (kernel vs jnp oracle); Sq == 1 covers the
    scheduler's one-token seeding chunk."""
    ks = jax.random.split(jax.random.key(11), 3)
    q = _rand(ks[0], (B, Sq, H, D), dtype)
    kc = _rand(ks[1], (B, Smax, Hkv, D), dtype)
    vc = _rand(ks[2], (B, Smax, Hkv, D), dtype)
    pos = jnp.asarray(list(range(0, B * 37, 37))[:B], jnp.int32)
    out = ops.prefill_attention(q, kc.transpose(0, 2, 1, 3),
                                vc.transpose(0, 2, 1, 3), pos, block_k=64)
    want = ref.ref_prefill_attention(q, kc, vc, pos)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_prefill_attention_paged_matches_dense():
    """Paged chunked-prefill through a shuffled page table equals the dense
    chunk over the same logical KV, including partially-mapped rows."""
    B, Smax, Sq, H, Hkv, D, ps = 3, 128, 8, 8, 2, 64, 16
    P = Smax // ps
    n_pages = 32
    ks = jax.random.split(jax.random.key(12), 3)
    q = _rand(ks[0], (B, Sq, H, D), jnp.float32)
    kc = _rand(ks[1], (B, Smax, Hkv, D), jnp.float32)
    vc = _rand(ks[2], (B, Smax, Hkv, D), jnp.float32)
    pos = jnp.asarray([0, 40, 120], jnp.int32)     # chunk ends at pos+Sq-1
    rng = np.random.default_rng(1)
    pages = rng.permutation(n_pages)[:B * P].reshape(B, P)
    kp = np.zeros((n_pages, Hkv, ps, D), np.float32)
    vp = np.zeros((n_pages, Hkv, ps, D), np.float32)
    for b in range(B):
        for j in range(P):
            kp[pages[b, j]] = np.asarray(kc)[b, j * ps:(j + 1) * ps] \
                .transpose(1, 0, 2)
            vp[pages[b, j]] = np.asarray(vc)[b, j * ps:(j + 1) * ps] \
                .transpose(1, 0, 2)
    pt = pages.astype(np.int32)
    pt[0, 1:] = n_pages            # row 0 (chunk within page 0): unmapped
    out = ops.prefill_attention_paged(jnp.asarray(q), jnp.asarray(kp),
                                      jnp.asarray(vp), jnp.asarray(pt), pos)
    want = ref.ref_prefill_attention(q, kc, vc, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    wantp = ref.ref_prefill_attention_paged(jnp.asarray(q), jnp.asarray(kp),
                                            jnp.asarray(vp), jnp.asarray(pt),
                                            pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(wantp),
                               rtol=2e-5, atol=2e-5)


def test_prefill_attention_abort_progress():
    """The sub-chunk abort protocol: with a per-row position cap, the first
    ``abort`` rows are bit-equal to running a chunk of exactly ``abort``
    tokens, and ``progress`` reports min(abort, Sq) per row."""
    B, Sq, H, Hkv, Smax, D = 3, 8, 4, 2, 128, 64
    ks = jax.random.split(jax.random.key(23), 3)
    q = _rand(ks[0], (B, Sq, H, D), jnp.float32)
    kc = _rand(ks[1], (B, Hkv, Smax, D), jnp.float32)
    vc = _rand(ks[2], (B, Hkv, Smax, D), jnp.float32)
    pos = jnp.asarray([0, 13, 77], jnp.int32)
    full = ops.prefill_attention(q, kc, vc, pos, block_k=32)
    abort = jnp.asarray([3, 8, 0], jnp.int32)
    out, prog = ops.prefill_attention(q, kc, vc, pos, block_k=32,
                                      abort=abort)
    np.testing.assert_array_equal(np.asarray(prog), [3, 8, 0])
    np.testing.assert_array_equal(np.asarray(out)[0, :3],
                                  np.asarray(full)[0, :3])
    np.testing.assert_array_equal(np.asarray(out)[1], np.asarray(full)[1])
    # an aborted prefix equals a genuinely smaller chunk (the resume
    # contract: a resumed chunk is just a smaller chunk)
    small = ops.prefill_attention(q[:, :3], kc, vc, pos, block_k=32)
    np.testing.assert_array_equal(np.asarray(out)[0, :3],
                                  np.asarray(small)[0])


def test_prefill_attention_paged_abort_progress():
    """Same protocol through the paged entry point: abort caps agree with
    the dense kernel and unmapped pages past the cap stay untouched."""
    B, Smax, Sq, H, Hkv, D, ps = 2, 128, 8, 4, 2, 64, 16
    P = Smax // ps
    n_pages = 24
    ks = jax.random.split(jax.random.key(24), 3)
    q = _rand(ks[0], (B, Sq, H, D), jnp.float32)
    kc = _rand(ks[1], (B, Smax, Hkv, D), jnp.float32)
    vc = _rand(ks[2], (B, Smax, Hkv, D), jnp.float32)
    pos = jnp.asarray([0, 40], jnp.int32)
    rng = np.random.default_rng(3)
    pages = rng.permutation(n_pages)[:B * P].reshape(B, P)
    kp = np.zeros((n_pages, Hkv, ps, D), np.float32)
    vp = np.zeros((n_pages, Hkv, ps, D), np.float32)
    for b in range(B):
        for j in range(P):
            kp[pages[b, j]] = np.asarray(kc)[b, j * ps:(j + 1) * ps] \
                .transpose(1, 0, 2)
            vp[pages[b, j]] = np.asarray(vc)[b, j * ps:(j + 1) * ps] \
                .transpose(1, 0, 2)
    abort = jnp.asarray([5, 2], jnp.int32)
    out, prog = ops.prefill_attention_paged(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
        jnp.asarray(pages.astype(np.int32)), pos, abort=abort)
    dense, dprog = ops.prefill_attention(
        q, jnp.asarray(kc).transpose(0, 2, 1, 3),
        jnp.asarray(vc).transpose(0, 2, 1, 3), pos, block_k=ps, abort=abort)
    np.testing.assert_array_equal(np.asarray(prog), np.asarray(dprog))
    np.testing.assert_allclose(np.asarray(out)[0, :5],
                               np.asarray(dense)[0, :5], rtol=2e-6,
                               atol=2e-6)
    np.testing.assert_allclose(np.asarray(out)[1, :2],
                               np.asarray(dense)[1, :2], rtol=2e-6,
                               atol=2e-6)


def test_interpret_autodetect():
    """``interpret=None`` resolves from the backend (CPU hosts interpret)
    and matches an explicit ``interpret=True`` bit-for-bit."""
    from repro.kernels.pallas_compat import interpret_default
    assert interpret_default() == (jax.default_backend() != "tpu")
    B, Smax, H, Hkv, D = 2, 64, 4, 2, 64
    ks = jax.random.split(jax.random.key(25), 3)
    q = _rand(ks[0], (B, 4, H, D), jnp.float32)
    kc = _rand(ks[1], (B, Hkv, Smax, D), jnp.float32)
    vc = _rand(ks[2], (B, Hkv, Smax, D), jnp.float32)
    pos = jnp.asarray([0, 9], jnp.int32)
    auto = ops.prefill_attention(q, kc, vc, pos, block_k=32)
    explicit = ops.prefill_attention(q, kc, vc, pos, block_k=32,
                                     interpret=True)
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(explicit))
    d_auto = ops.decode_attention(q[:, 0], kc, vc, pos, block_k=32,
                                  kv_layout="bhsd")
    d_explicit = ops.decode_attention(q[:, 0], kc, vc, pos, block_k=32,
                                      kv_layout="bhsd", interpret=True)
    np.testing.assert_array_equal(np.asarray(d_auto), np.asarray(d_explicit))


def test_prefill_attention_reduces_to_decode():
    """An Sq == 1 prefill chunk is exactly a decode step (the bit-stable
    seeding-chunk contract)."""
    B, Smax, H, Hkv, D = 2, 128, 4, 2, 64
    ks = jax.random.split(jax.random.key(13), 3)
    q = _rand(ks[0], (B, 1, H, D), jnp.float32)
    kc = _rand(ks[1], (B, Hkv, Smax, D), jnp.float32)
    vc = _rand(ks[2], (B, Hkv, Smax, D), jnp.float32)
    pos = jnp.asarray([3, 90], jnp.int32)
    a = ops.prefill_attention(q, kc, vc, pos, block_k=32)
    b = ops.decode_attention(q[:, 0], kc, vc, pos, block_k=32,
                             kv_layout="bhsd")
    np.testing.assert_allclose(np.asarray(a[:, 0]), np.asarray(b),
                               rtol=2e-6, atol=2e-6)
