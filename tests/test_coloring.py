"""VRAM-channel coloring stack: hash models, probes (Algo 1-3), granularity,
MLP fit, colored allocator."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.coloring import (ColoredArena, OutOfColoredMemory, VRAMDevice,
                                 collect_samples, fit_channel_hash,
                                 gpu_hash_model, is_channel_conflicted,
                                 measure_granularity, split_channels)


def test_hash_models_cover_channels():
    for gpu, n in [("tesla-p40", 12), ("rtx-a2000", 6), ("rtx-a5500", 12),
                   ("tesla-v100", 32)]:
        hm = gpu_hash_model(gpu)
        assert hm.num_channels == n
        addrs = np.arange(0, 4 << 20, 1024)
        ch = hm.channel_of(addrs)
        counts = np.bincount(ch, minlength=n)
        # uniform distribution across the space (paper Fig. 18)
        assert counts.min() > 0.7 * counts.mean(), (gpu, counts)


def test_permutation_hash_is_nonlinear():
    """XOR-linearity test: h(a) ^ h(b) ^ h(a^b) ^ h(0) == 0 for linear maps;
    the permutation hash must violate it somewhere (the paper's core
    observation about P40/A2000-class GPUs)."""
    hm = gpu_hash_model("tesla-p40")
    rng = np.random.default_rng(0)
    a = (rng.integers(0, 4096, 200) * 1024).astype(np.int64)
    b = (rng.integers(0, 4096, 200) * 1024).astype(np.int64)
    ha, hb = hm.channel_of(a), hm.channel_of(b)
    hxor = hm.channel_of(a ^ b)
    h0 = hm.channel_of(np.zeros(1, np.int64))[0]
    assert np.any((ha ^ hb ^ hxor ^ h0) != 0)


def test_algo1_pairwise_conflict():
    hm = gpu_hash_model("rtx-a2000")
    dev = VRAMDevice(hm, seed=3)
    addrs = np.arange(0, 256 * 1024, 1024)
    ch = hm.channel_of(addrs)
    same = np.nonzero(ch == ch[0])[0]
    diff = np.nonzero(ch != ch[0])[0]
    assert is_channel_conflicted(dev, int(addrs[same[0]]),
                                 int(addrs[same[1]]))
    assert not is_channel_conflicted(dev, int(addrs[same[0]]),
                                     int(addrs[diff[0]]))


def test_reveng_finds_channels_and_granularity():
    hm = gpu_hash_model("rtx-a2000")
    dev = VRAMDevice(hm, seed=1)
    res = collect_samples(dev, 2 << 20, 150, seed=0)
    assert res.num_channels_found == hm.num_channels
    assert res.label_accuracy > 0.97
    assert measure_granularity(dev) == 2048    # A2000: 2 KiB runs (Tab. 7)


@pytest.mark.slow
def test_mlp_fit_high_accuracy():
    hm = gpu_hash_model("rtx-a2000")
    rng = np.random.default_rng(0)
    addrs = (rng.choice(8192, 3000, replace=False) * 1024).astype(np.int64)
    labels = hm.channel_of(addrs)
    fit = fit_channel_hash(addrs, labels, 1024, hm.num_channels,
                           steps=1200, hidden=128, depth=6, n_bits=14, seed=0)
    assert fit.test_acc > 0.95, fit.test_acc


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------

def _arena(gpu="tesla-p40", mb=4):
    hm = gpu_hash_model(gpu)
    return ColoredArena(mb << 20, hm.channel_of, hm.num_channels,
                        hm.granularity), hm


def test_allocator_respects_colors():
    arena, hm = _arena()
    ls, be = split_channels(hm.num_channels, 1 / 3)
    a = arena.alloc("ls_w", 512 * 1024, ls)
    b = arena.alloc("be_w", 256 * 1024, be)
    assert arena.isolation_violations(a) == 0
    assert arena.isolation_violations(b) == 0
    assert set(np.nonzero(arena.channel_histogram(a))[0]).issubset(set(ls))
    assert set(np.nonzero(arena.channel_histogram(b))[0]).issubset(set(be))
    arena.release("ls_w")
    arena.alloc("ls_w2", 512 * 1024, ls)   # reuse freed pages


def test_allocator_oom_on_exhausted_colors():
    arena, hm = _arena(mb=1)
    ls, be = split_channels(hm.num_channels, 1 / 3)
    with pytest.raises(OutOfColoredMemory):
        arena.alloc("big", 10 << 20, be)


@given(frac=st.floats(0.05, 0.95))
@settings(max_examples=20, deadline=None)
def test_split_channels_property(frac):
    ls, be = split_channels(12, frac)
    assert set(ls) | set(be) == set(range(12))
    assert not (set(ls) & set(be))
    assert len(be) >= 1 and len(ls) >= 1
