"""VRAM-channel coloring stack: hash models, probes (Algo 1-3), granularity,
MLP fit, colored allocator."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.coloring import (ColoredArena, OutOfColoredMemory, VRAMDevice,
                                 collect_samples, fit_channel_hash,
                                 gpu_hash_model, is_channel_conflicted,
                                 measure_granularity, split_channels)


def test_hash_models_cover_channels():
    for gpu, n in [("tesla-p40", 12), ("rtx-a2000", 6), ("rtx-a5500", 12),
                   ("tesla-v100", 32)]:
        hm = gpu_hash_model(gpu)
        assert hm.num_channels == n
        addrs = np.arange(0, 4 << 20, 1024)
        ch = hm.channel_of(addrs)
        counts = np.bincount(ch, minlength=n)
        # uniform distribution across the space (paper Fig. 18)
        assert counts.min() > 0.7 * counts.mean(), (gpu, counts)


def test_permutation_hash_is_nonlinear():
    """XOR-linearity test: h(a) ^ h(b) ^ h(a^b) ^ h(0) == 0 for linear maps;
    the permutation hash must violate it somewhere (the paper's core
    observation about P40/A2000-class GPUs)."""
    hm = gpu_hash_model("tesla-p40")
    rng = np.random.default_rng(0)
    a = (rng.integers(0, 4096, 200) * 1024).astype(np.int64)
    b = (rng.integers(0, 4096, 200) * 1024).astype(np.int64)
    ha, hb = hm.channel_of(a), hm.channel_of(b)
    hxor = hm.channel_of(a ^ b)
    h0 = hm.channel_of(np.zeros(1, np.int64))[0]
    assert np.any((ha ^ hb ^ hxor ^ h0) != 0)


def test_algo1_pairwise_conflict():
    hm = gpu_hash_model("rtx-a2000")
    dev = VRAMDevice(hm, seed=3)
    addrs = np.arange(0, 256 * 1024, 1024)
    ch = hm.channel_of(addrs)
    same = np.nonzero(ch == ch[0])[0]
    diff = np.nonzero(ch != ch[0])[0]
    assert is_channel_conflicted(dev, int(addrs[same[0]]),
                                 int(addrs[same[1]]))
    assert not is_channel_conflicted(dev, int(addrs[same[0]]),
                                     int(addrs[diff[0]]))


def test_reveng_finds_channels_and_granularity():
    hm = gpu_hash_model("rtx-a2000")
    dev = VRAMDevice(hm, seed=1)
    res = collect_samples(dev, 2 << 20, 150, seed=0)
    assert res.num_channels_found == hm.num_channels
    assert res.label_accuracy > 0.97
    assert measure_granularity(dev) == 2048    # A2000: 2 KiB runs (Tab. 7)


@pytest.mark.slow
def test_mlp_fit_high_accuracy():
    hm = gpu_hash_model("rtx-a2000")
    rng = np.random.default_rng(0)
    addrs = (rng.choice(8192, 3000, replace=False) * 1024).astype(np.int64)
    labels = hm.channel_of(addrs)
    fit = fit_channel_hash(addrs, labels, 1024, hm.num_channels,
                           steps=1200, hidden=128, depth=6, n_bits=14, seed=0)
    assert fit.test_acc > 0.95, fit.test_acc


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------

def _arena(gpu="tesla-p40", mb=4):
    hm = gpu_hash_model(gpu)
    return ColoredArena(mb << 20, hm.channel_of, hm.num_channels,
                        hm.granularity), hm


def test_allocator_respects_colors():
    arena, hm = _arena()
    ls, be = split_channels(hm.num_channels, 1 / 3)
    a = arena.alloc("ls_w", 512 * 1024, ls)
    b = arena.alloc("be_w", 256 * 1024, be)
    assert arena.isolation_violations(a) == 0
    assert arena.isolation_violations(b) == 0
    assert set(np.nonzero(arena.channel_histogram(a))[0]).issubset(set(ls))
    assert set(np.nonzero(arena.channel_histogram(b))[0]).issubset(set(be))
    arena.release("ls_w")
    arena.alloc("ls_w2", 512 * 1024, ls)   # reuse freed pages


def test_allocator_oom_on_exhausted_colors():
    arena, hm = _arena(mb=1)
    ls, be = split_channels(hm.num_channels, 1 / 3)
    with pytest.raises(OutOfColoredMemory):
        arena.alloc("big", 10 << 20, be)


def _all_pages_accounted(arena):
    """Every arena page is exactly once in a free list or an SPT."""
    free = [p for lst in arena.free for p in lst]
    held = [int(p) for a in arena.allocations.values() for p in a.spt]
    assert len(free) + len(held) == len(arena.page_channel)
    assert len(set(free) | set(held)) == len(arena.page_channel)


def test_resplit_migrates_and_conserves_pages():
    arena, hm = _arena()
    ls, be = split_channels(hm.num_channels, 1 / 4)
    a = arena.alloc("ls_w", 512 * 1024, ls)
    b = arena.alloc("be_w", 256 * 1024, be)
    ls2, be2 = split_channels(hm.num_channels, 1 / 2)
    moved = arena.resplit({"ls_w": ls2, "be_w": be2})
    # BE widened onto former-LS channels; LS vacated them
    assert moved["be_w"] == 0 or arena.isolation_violations(b) == 0
    assert arena.isolation_violations(a) == 0
    assert arena.isolation_violations(b) == 0
    assert a.channels == ls2 and b.channels == be2
    _all_pages_accounted(arena)


def test_resplit_repeated_keeps_ls_clean():
    """The tidal cycle: repeated ch_be moves (including full lending, where
    BE's set covers LS's) never leave an LS page off-color or leak pages."""
    arena, hm = _arena()
    every = tuple(range(hm.num_channels))
    ls, be = split_channels(hm.num_channels, 1 / 3)
    a = arena.alloc("ls_w", 768 * 1024, ls)
    b = arena.alloc("be_w", 512 * 1024, be)
    for ch_be in (1 / 2, 1 / 6, None, 1 / 4, None, 1 / 3):
        if ch_be is None:      # lending: BE borrows everything
            arena.resplit({"be_w": every})
        else:
            ls_c, be_c = split_channels(hm.num_channels, ch_be)
            arena.resplit({"ls_w": ls_c, "be_w": be_c})
        assert arena.isolation_violations(a) == 0
        _all_pages_accounted(arena)
    assert arena.isolation_violations(b) == 0


def test_resplit_best_effort_and_unknown_names():
    """Off-color pages with no free destination stay put (counted as
    violations, to be drained later) instead of raising; names not in the
    arena are skipped."""
    arena, hm = _arena(mb=1)
    ls, be = split_channels(hm.num_channels, 1 / 3)
    # fill LS channels almost completely, then try to squeeze BE into them
    a = arena.alloc("ls_w", arena.free_pages(ls) * hm.granularity, ls)
    b = arena.alloc("be_w", 128 * 1024, be)
    moved = arena.resplit({"be_w": ls, "ghost": be})
    assert "ghost" not in moved
    assert arena.isolation_violations(b) == b.n_pages - moved["be_w"]
    _all_pages_accounted(arena)


@given(frac=st.floats(0.05, 0.95))
@settings(max_examples=20, deadline=None)
def test_split_channels_property(frac):
    ls, be = split_channels(12, frac)
    assert set(ls) | set(be) == set(range(12))
    assert not (set(ls) & set(be))
    assert len(be) >= 1 and len(ls) >= 1
