"""Model-swapping scenario (paper §8.4): models live in host memory and
stream over the interconnect before serving; compare PCIe schedulers and
show the CFS nice-weight knob trading LS latency vs BE throughput — then
serve the swapped-in models through the continuous-batching ServingEngine
(cold-start swap -> plan-driven serving, end to end).

Run:  PYTHONPATH=src python examples/swap_serving.py
"""
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core.pcie import (BusSpec, MultiStream, PCIeCFS, StreamBox,
                             summarize)
from repro.core.simulator import TPU_V5E, apollo_like_trace
from repro.core.tenancy import TenantSpec
from repro.serving import ServingEngine
from repro.serving.swap import (model_bytes, pipelined_serve_time,
                                swap_requests)

HORIZON = 8.0
bus = BusSpec()
ls_archs = ["qwen3-1.7b", "stablelm-1.6b"]
be_archs = ["gemma2-9b"]

for arch in ls_archs + be_archs:
    mb = model_bytes(get_config(arch)) / 2**30
    t = pipelined_serve_time(get_config(arch), 1, 128, "prefill", TPU_V5E,
                             bus.bw_h2d)
    print(f"{arch:<18s} weights {mb:5.2f} GiB, cold-serve "
          f"(PipeSwitch overlap) {t*1e3:7.1f} ms")

print(f"\n{'scheduler':<14s} {'LS swap p99 (ms)':>17s} {'BE thpt':>10s}")
for name, sched, nice in [("multistream", MultiStream(), 1),
                          ("streambox", StreamBox(), 1),
                          ("cfs nice=1", PCIeCFS(2048), 1),
                          ("cfs nice=20", PCIeCFS(2048), 20),
                          ("cfs nice=10K", PCIeCFS(2048), 10_000)]:
    reqs, rid = [], 0
    for i, arch in enumerate(ls_archs):
        arr = apollo_like_trace(1.5, HORIZON, seed=i + 1)
        reqs += swap_requests(get_config(arch), f"ls:{arch}", "LS", nice, arr,
                              rid0=rid)
        rid += 100_000
    for arch in be_archs:
        arr = list(np.arange(0.0, HORIZON, 0.8))
        reqs += swap_requests(get_config(arch), f"be:{arch}", "BE", 100, arr,
                              rid0=rid)
        rid += 100_000
    comps = [c for c in sched.run(reqs, bus, "h2d") if c.t_done < HORIZON]
    p99, thpt, _ = summarize(comps)
    print(f"{name:<14s} {p99*1e3:>17.1f} {thpt/2**30:>8.2f}GiB/s")

# -- after the swap: serve the hot models through the batching engine --------
print("\nswapped-in models serving (continuous batching, reduced scale):")
eng = ServingEngine(max_seq=16, slots_ls=2, slots_be=2)
eng.add_tenant(TenantSpec("ls:qwen3", "LS", nice=10_000),
               smoke_config("qwen3-1.7b").replace(
                   num_layers=1, activation_dtype="float32"))
eng.add_tenant(TenantSpec("be:gemma2", "BE", nice=1),
               smoke_config("gemma2-9b").replace(
                   num_layers=2, activation_dtype="float32"))
rng = np.random.default_rng(1)
for _ in range(3):
    eng.submit("ls:qwen3", rng.integers(0, 200, 5), max_new=3)
    eng.submit("be:gemma2", rng.integers(0, 200, 5), max_new=3)
eng.run_until_idle()
m = eng.metrics()
for cls in ("LS", "BE"):
    c = m["_class"][cls]
    print(f"  {cls}: {c['completed']} done, p99 {c['p99_ms']:.0f} ms, "
          f"{c['tokens_per_s']:.1f} tok/s")
