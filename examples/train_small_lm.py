"""Train a small LM for a few hundred steps with checkpointing and a
simulated mid-run failure + restart (fault-tolerance demo).

Run:  PYTHONPATH=src python examples/train_small_lm.py  [--steps 200]
"""
import argparse
import tempfile

from repro.configs import smoke_config
from repro.train import AdamWConfig, DataConfig, Trainer, TrainerConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--arch", default="stablelm-1.6b")
args = ap.parse_args()

cfg = smoke_config(args.arch).replace(num_layers=2)
dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8,
                mode="pattern")
oc = AdamWConfig(lr=2e-3, warmup_steps=10, total_steps=args.steps)

with tempfile.TemporaryDirectory() as ckpt_dir:
    half = args.steps // 2
    t1 = Trainer(cfg, dc, oc, TrainerConfig(steps=half, ckpt_dir=ckpt_dir,
                                            ckpt_every=25))
    t1.run()
    print(f"[phase 1] trained to step {t1.step}, "
          f"loss {t1.history[-1]['loss']:.3f} — simulating node failure...")
    del t1  # "crash"

    t2 = Trainer(cfg, dc, oc, TrainerConfig(steps=args.steps,
                                            ckpt_dir=ckpt_dir,
                                            ckpt_every=25))
    print(f"[phase 2] auto-resumed at step {t2.step}")
    hist = t2.run()
    for h in hist[:: max(1, len(hist) // 8)]:
        print(f"  step {h['step']:4d}  loss {h['loss']:.4f}")
    print(f"final loss {hist[-1]['loss']:.4f} "
          f"(started near ln(V)={__import__('math').log(cfg.vocab_size):.2f})")
