"""Quickstart: the three faces of the framework in ~60 lines.

  1. train a reduced LM for a few steps (loss goes down),
  2. reverse-engineer a simulated GPU's VRAM channel hash and fit the MLP,
  3. serve one LS + one BE tenant through the continuous-batching engine
     with SGDRC isolation (coloring + a ResourcePlan's BE quantum share)
     and print per-class p99s.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs import smoke_config
from repro.core.coloring import (VRAMDevice, collect_samples,
                                 fit_channel_hash, gpu_hash_model)
from repro.core.controller import grid_search
from repro.core.simulator import GPU_DEVICES
from repro.core.tenancy import TenantSpec
from repro.serving import ServingEngine
from repro.train import AdamWConfig, DataConfig, Trainer, TrainerConfig

# -- 1. train ---------------------------------------------------------------
cfg = smoke_config("qwen3-1.7b").replace(num_layers=2)
dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
trainer = Trainer(cfg, dc, AdamWConfig(lr=1e-3, warmup_steps=3,
                                       total_steps=30),
                  TrainerConfig(steps=15))
hist = trainer.run()
print(f"[train] loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
      f"over {len(hist)} steps")
assert hist[-1]["loss"] < hist[0]["loss"]

# -- 2. reverse-engineer + fit the channel hash ------------------------------
hm = gpu_hash_model("rtx-a2000")
dev = VRAMDevice(hm, seed=1)
res = collect_samples(dev, 2 << 20, 400, seed=0)
fit = fit_channel_hash(res.addrs[res.labels >= 0],
                       res.labels[res.labels >= 0], hm.granularity,
                       res.num_channels_found, steps=800, hidden=96, depth=5,
                       n_bits=12)
print(f"[reveng] found {res.num_channels_found} channels "
      f"(true {hm.num_channels}), probe acc {res.label_accuracy:.3f}, "
      f"MLP test acc {fit.test_acc:.3f}")

# -- 3. serve LS + BE with SGDRC isolation -----------------------------------
# offline: grid-search the (SM_BE, Ch_BE, Thres_DRAM) plan on a device model;
# online: the engine lends BE the plan's quantum share and colors KV arenas.
plan = grid_search(GPU_DEVICES["rtx-a2000"],
                   [smoke_config("stablelm-1.6b")],
                   [smoke_config("gemma2-9b")], pairs_per_model=1)
eng = ServingEngine(max_seq=24, coloring=True, hash_model=hm, plan=plan,
                    arena_bytes=8 << 20, slots_ls=3, slots_be=2)
eng.add_tenant(TenantSpec("ls", "LS", nice=10_000, slo_ms=60_000.0),
               smoke_config("stablelm-1.6b").replace(
                   num_layers=1, activation_dtype="float32"))
eng.add_tenant(TenantSpec("be", "BE", nice=1),
               smoke_config("gemma2-9b").replace(
                   num_layers=2, activation_dtype="float32"))
rng = np.random.default_rng(0)
for _ in range(3):
    eng.submit("ls", rng.integers(0, 100, 6), max_new=3)
    eng.submit("be", rng.integers(0, 100, 6), max_new=3)
eng.run_until_idle()
m = eng.metrics()
print(f"[serve] plan SM_BE={plan.sm_be:.2f} Ch_BE={plan.ch_be:.2f} | "
      f"LS p99 {m['_class']['LS']['p99_ms']:.0f} ms "
      f"(SLO attainment {m['_class']['LS']['slo_attainment']:.0%}) | "
      f"BE p99 {m['_class']['BE']['p99_ms']:.0f} ms | "
      f"coloring violations: "
      f"{sum(v['violations'] for v in m['_coloring'].values())}")
print("quickstart OK")
