"""End-to-end driver (the paper's kind of system is a server): multi-tenant
DNN inference with batched requests, comparing SGDRC against the baseline
GPU-sharing policies on the full-size assigned architectures (contention
simulator) AND running the reduced models for real on the local device.

Run:  PYTHONPATH=src python examples/serve_multitenant.py
"""
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core import (ComputePolicy, GPUSimulator, TPU_V5E, Tenant,
                        poisson_trace, request_kernels)
from repro.core.coloring import gpu_hash_model
from repro.core.tenancy import TenantSpec
from repro.serving import ServingEngine

HORIZON = 5.0

# -- pod-scale what-if on the full configs (simulator) ----------------------
dev = TPU_V5E
ls_k = request_kernels(get_config("qwen3-1.7b"), 1, 128, "prefill", dev)
be_k = request_kernels(get_config("gemma2-9b"), 8, 256, "prefill", dev)
print(f"{'policy':<22s} {'LS p99 (ms)':>12s} {'BE thpt (samp/s)':>18s}")
for policy, coloring in [("temporal", False), ("spatial", False),
                         ("orion", False), ("sgdrc", False),
                         ("sgdrc", True)]:
    tenants = [
        Tenant("ls0", "LS", ls_k, arrivals=poisson_trace(30, HORIZON, 1)),
        Tenant("ls1", "LS", ls_k, arrivals=poisson_trace(30, HORIZON, 2)),
        Tenant("be0", "BE", be_k, closed_loop=True),
    ]
    res = GPUSimulator(dev, ComputePolicy(kind=policy),
                       coloring=coloring).run(tenants, HORIZON)
    tag = policy + ("+coloring" if coloring else "")
    print(f"{tag:<22s} {res.ls_p99()*1e3:>12.1f} "
          f"{res.be_throughput(8):>18.1f}")

# -- real execution at reduced scale (local device) --------------------------
print("\nreal-JAX reduced-scale serving (LS preempts BE between steps):")
eng = ServingEngine(max_seq=20, coloring=True,
                    hash_model=gpu_hash_model("tesla-p40"),
                    arena_bytes=8 << 20)
eng.add_tenant(TenantSpec("ls:qwen3", "LS", nice=10_000),
               smoke_config("qwen3-1.7b").replace(
                   num_layers=2, activation_dtype="float32"))
eng.add_tenant(TenantSpec("be:gemma2", "BE", nice=1),
               smoke_config("gemma2-9b").replace(
                   num_layers=2, activation_dtype="float32"))
rng = np.random.default_rng(0)
for i in range(4):
    eng.submit("ls:qwen3", rng.integers(0, 200, 6), max_new=4)
    eng.submit("be:gemma2", rng.integers(0, 200, 6), max_new=4)
eng.run_until_idle()
import json
print(json.dumps(eng.metrics(), indent=1))
