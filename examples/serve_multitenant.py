"""End-to-end driver (the paper's kind of system is a server): multi-tenant
DNN inference with batched requests, comparing SGDRC against the baseline
GPU-sharing policies on the full-size assigned architectures (sim backend)
AND running the reduced models for real with continuous batching (jax
backend) — both through the SAME ServingEngine API, with the offline
controller's ResourcePlan threaded into each.

Run:  PYTHONPATH=src python examples/serve_multitenant.py
      (add --trace to attach the telemetry plane to the sgdrc+online run
      and print its SLO-timeline violation-attribution table)
"""
import json
import sys

import numpy as np

from repro import obs
from repro.configs import get_config, smoke_config
from repro.core.controller import OnlineController, grid_search, tidal_frontier
from repro.core.coloring import gpu_hash_model
from repro.core.simulator import TPU_V5E, poisson_trace
from repro.core.tenancy import TenantSpec
from repro.serving import ServingEngine

HORIZON = 5.0

# -- offline phase: derive the ResourcePlan once ----------------------------
plan = grid_search(TPU_V5E, [smoke_config("qwen3-1.7b")],
                   [smoke_config("gemma2-9b")], pairs_per_model=2)
print(f"plan: SM_BE={plan.sm_be:.2f} Ch_BE={plan.ch_be:.2f} "
      f"Thres_DRAM={plan.thres_dram:.2f}")

# -- pod-scale what-if on the full configs (sim backend) --------------------
# "sgdrc+online" adds the online control plane on top of full SGDRC: a
# tidal controller over the plan's two-point frontier re-plans sm_be/ch_be
# every 5 simulated ms, lending BE the whole machine between LS arrivals
print(f"\n{'policy':<22s} {'LS p99 (ms)':>12s} {'BE thpt (samp/s)':>18s}")
for policy, coloring, online in [("temporal", False, False),
                                 ("spatial", False, False),
                                 ("orion", False, False),
                                 ("sgdrc", False, False),
                                 ("sgdrc", True, False),
                                 ("sgdrc", True, True)]:
    ctrl = OnlineController(tidal_frontier(plan)) if online else None
    # --trace: attach the telemetry plane to the sgdrc+online row and give
    # the LS tenants an SLO so request:done events carry verdicts the
    # SLOTimeline can score and attribute
    tracer = obs.Tracer("info") if ("--trace" in sys.argv and online) else None
    ls_slo = 15.0 if tracer is not None else None
    eng = ServingEngine(backend="sim", device="tpu-v5e", policy=policy,
                        coloring=coloring, plan=plan, controller=ctrl,
                        control_dt=0.005, tracer=tracer)
    eng.add_tenant(TenantSpec("ls0", "LS", batch_size=1, slo_ms=ls_slo),
                   get_config("qwen3-1.7b"), sim_seq=128)
    eng.add_tenant(TenantSpec("ls1", "LS", batch_size=1, slo_ms=ls_slo),
                   get_config("qwen3-1.7b"), sim_seq=128)
    eng.add_tenant(TenantSpec("be0", "BE", batch_size=8),
                   get_config("gemma2-9b"), closed_loop=True, sim_seq=256)
    for i, name in enumerate(("ls0", "ls1")):
        for t in poisson_trace(30, HORIZON, i + 1):
            eng.submit(name, np.zeros(1, np.int32), max_new=0, at=t)
    eng.run_until_idle(horizon=HORIZON)
    res = eng.sim_result
    tag = policy + ("+coloring" if coloring else "") + \
        ("+online" if online else "")
    print(f"{tag:<22s} {res.ls_p99()*1e3:>12.1f} "
          f"{res.be_throughput(8):>18.1f}")
    if tracer is not None:
        tl = obs.SLOTimeline(tracer.events, window=HORIZON / 10)
        print(f"\nSLO timeline ({tag}, {tracer.stats()['events']} events, "
              f"LS SLO {ls_slo:.0f}ms): violation attribution")
        print(tl.format_table())
        print()

# -- real execution at reduced scale (jax backend) ---------------------------
# paged colored KV + radix-tree prefix cache: the repeated system prompt is
# prefilled once and shared copy-on-write into later slots' page tables
print("\nreal-JAX reduced-scale continuous-batching serving "
      "(plan-driven BE quantum share + online tidal re-planning "
      "+ prefix-cache page sharing):")
ctrl = OnlineController(tidal_frontier(plan, 12), idle_patience=1)
eng = ServingEngine(max_seq=20, coloring=True, plan=plan,
                    hash_model=gpu_hash_model("tesla-p40"),
                    arena_bytes=8 << 20, slots_ls=4, slots_be=2,
                    paged=True, page_size=4, prefix_cache=True,
                    controller=ctrl, control_interval=2)
eng.add_tenant(TenantSpec("ls:qwen3", "LS", nice=10_000),
               smoke_config("qwen3-1.7b").replace(
                   num_layers=2, activation_dtype="float32"))
eng.add_tenant(TenantSpec("be:gemma2", "BE", nice=1),
               smoke_config("gemma2-9b").replace(
                   num_layers=2, activation_dtype="float32"))
rng = np.random.default_rng(0)
system_prompt = rng.integers(0, 200, 4)
for i in range(4):
    eng.submit("ls:qwen3",
               np.concatenate([system_prompt, rng.integers(0, 200, 2)]),
               max_new=4)
    eng.submit("be:gemma2",
               np.concatenate([system_prompt, rng.integers(0, 200, 2)]),
               max_new=4)
    eng.run_until_idle()
print(json.dumps(eng.metrics(), indent=1))
print(f"online transitions: {len(eng.transitions)} "
      f"(pages moved: {sum(t['pages_moved'] for t in eng.transitions)}, "
      f"migrated bytes: {eng.migrated_bytes})")

# -- KV memory hierarchy: growth + host-tier page swap -----------------------
# a deliberately tiny page pool oversubscribes the arena: requests admit on
# prompt-extent pages only, grow page-by-page while decoding, and on
# exhaustion cold decode page groups swap to a quantized (int8) host tier
# over the PCIe CFS instead of being recomputed from scratch
print("\nKV hierarchy under an oversubscribed pool "
      "(--grow-pages --swap --cold-dtype int8 in the launcher):")
eng = ServingEngine(max_seq=20, paged=True, page_size=4, kv_pages=10,
                    grow_pages=True, swap=True, cold_dtype="int8",
                    slots_ls=8, slots_be=8)
eng.add_tenant(TenantSpec("be:gemma2", "BE"),
               smoke_config("gemma2-9b").replace(
                   num_layers=2, activation_dtype="float32"))
rng = np.random.default_rng(1)
reqs = [eng.submit("be:gemma2", rng.integers(0, 200, 8), max_new=10)
        for _ in range(6)]
eng.run_until_idle()
m = eng.metrics()["be:gemma2"]
print(f"peak concurrent slots: {m['peak_active']} "
      f"(vs {10 * 4 // 20} with full-extent reservation on the same pool)")
print("swap:", json.dumps(m["swap"], indent=1))
